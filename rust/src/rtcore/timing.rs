//! Roofline timing model: operation counts → simulated phase times.
//!
//! Each pipeline phase is priced as `max(compute time, memory time)` plus a
//! kernel-launch overhead — the standard roofline treatment. The absolute
//! rates live in [`super::profile`]; this module only encodes the *shape*
//! of each phase (which units it stresses, how many bytes it moves).

use super::profile::HwProfile;
use super::OpCounts;

/// Child-box tests per counted `aabb_tests` unit. The traversal counts one
/// unit per **4-wide SoA node test** (see [`crate::bvh::traverse`]), while
/// the seed's binary-BVH calibration charged one unit per single box test.
/// Multiplying by the node width keeps the per-child-box compute charge —
/// and therefore simulated GPU time — comparable with the seed: a workload
/// that needed `k` single-box tests on the binary tree needs `~k/4` wide
/// tests here and is priced the same, and any *reduction* in priced time
/// reflects genuinely fewer boxes touched, not a unit change.
const BOX_TESTS_PER_AABB_UNIT: f64 = crate::bvh::BVH4_WIDTH as f64;

/// What one node fetch cost before quantization: 4 child boxes at the
/// seed's 2 B/box calibration (the uncompressed 128-byte `Bvh4Node`,
/// heavily L2-cached across rays). Kept as the reference point the
/// quantized pricing and the bench table's "quantized vs 128 B" rows are
/// measured against.
pub const BYTES_PER_NODE_FETCH_UNCOMPRESSED: f64 = 2.0 * BOX_TESTS_PER_AABB_UNIT;

/// Modeled bytes moved per operation (device-memory traffic, after cache).
/// One `aabb_tests` unit fetches a whole 4-wide node, scaled by the actual
/// quantized node size against the 128-byte layout the seed calibration
/// assumed — so shrinking `Bvh4Node` shrinks the priced traffic by exactly
/// the layout ratio, and nothing else changes. Note the meter stays
/// *honest* about the trade: quantized bounds are conservative, so a
/// quantized tree may visit MORE nodes than an exact tree would
/// (`aabb_tests` counts every one of them); the win is that each visit
/// moves fewer bytes.
pub const BYTES_PER_NODE_FETCH: f64 = 2.0
    * BOX_TESTS_PER_AABB_UNIT
    * (std::mem::size_of::<crate::bvh::Bvh4Node>() as f64 / 128.0);
const BYTES_PER_SPHERE_FETCH: f64 = 8.0; // center + radius + id, cached
const BYTES_PER_LIST_WRITE: f64 = 8.0; // index + bookkeeping
const BYTES_PER_FORCE_PAIR: f64 = 32.0; // gather: pos + radius of both ends
const BYTES_PER_INTEGRATE: f64 = 48.0; // pos + vel + force, read/write
const BYTES_PER_CELL_TEST: f64 = 16.0;
const BYTES_PER_SORT_ELEM: f64 = 32.0; // 4-pass radix, key+payload

/// Force evaluations executed *inside intersection shaders* run divergent
/// (rays hit at different times, shaders serialize against traversal) and
/// achieve a fraction of the throughput of a dense standalone force kernel.
/// This is why the paper's ORCS variants lose to RT-REF at large constant
/// radii (Table 2, r=160) despite doing strictly less memory traffic.
const IN_SHADER_DIVERGENCE: f64 = 2.5;

/// Simulated time per pipeline phase, seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimes {
    pub build: f64,
    pub refit: f64,
    /// RT traversal including in-shader work (intersection shaders, payload
    /// or atomic accumulation, neighbor-list writes).
    pub traverse: f64,
    /// Standalone force kernel (RT-REF).
    pub force_kernel: f64,
    pub integrate: f64,
    /// Grid build + z-order sort (cell methods).
    pub grid: f64,
    /// Cell-sweep force phase (cell methods).
    pub cell: f64,
}

impl PhaseTimes {
    /// Total simulated step time.
    pub fn total(&self) -> f64 {
        self.build + self.refit + self.traverse + self.force_kernel + self.integrate
            + self.grid
            + self.cell
    }

    /// The "RT cost" of the paper's Fig. 8: BVH maintenance + RT query.
    pub fn rt_cost(&self) -> f64 {
        self.build + self.refit + self.traverse
    }

    pub fn add(&mut self, o: &PhaseTimes) {
        self.build += o.build;
        self.refit += o.refit;
        self.traverse += o.traverse;
        self.force_kernel += o.force_kernel;
        self.integrate += o.integrate;
        self.grid += o.grid;
        self.cell += o.cell;
    }

    /// All phases multiplied by `f` (straggler-slowdown pricing: a
    /// throttled device runs every phase proportionally slower).
    pub fn scaled(&self, f: f64) -> PhaseTimes {
        PhaseTimes {
            build: self.build * f,
            refit: self.refit * f,
            traverse: self.traverse * f,
            force_kernel: self.force_kernel * f,
            integrate: self.integrate * f,
            grid: self.grid * f,
            cell: self.cell * f,
        }
    }
}

/// Price one step's operation counts on a hardware profile.
pub fn simulate(counts: &OpCounts, hw: &HwProfile) -> PhaseTimes {
    let launch = hw.launch_overhead_s;
    let mut t = PhaseTimes::default();

    if counts.bvh_built_prims > 0 {
        t.build = counts.bvh_built_prims as f64 / hw.bvh_build_rate + launch;
    }
    if counts.bvh_refit_prims > 0 {
        t.refit = counts.bvh_refit_prims as f64 / hw.bvh_refit_rate + launch;
    }

    if counts.rays > 0 {
        // RT-core box units, SM shading and memory run concurrently.
        let box_t = counts.aabb_tests as f64 * BOX_TESTS_PER_AABB_UNIT / hw.rt_box_rate;
        let shade_t = counts.sphere_tests as f64 / hw.rt_isect_rate
            + counts.isect_force_evals as f64 * IN_SHADER_DIVERGENCE / hw.pair_eval_rate
            + counts.payload_accums as f64 / (4.0 * hw.pair_eval_rate)
            + counts.atomic_adds as f64 / hw.atomic_rate;
        let mem_t = (counts.aabb_tests as f64 * BYTES_PER_NODE_FETCH
            + counts.sphere_tests as f64 * BYTES_PER_SPHERE_FETCH
            + counts.nbr_list_writes as f64 * BYTES_PER_LIST_WRITE)
            / hw.mem_bw;
        t.traverse = box_t.max(shade_t).max(mem_t) + launch;
    }

    if counts.force_kernel_pairs > 0 {
        let c = counts.force_kernel_pairs as f64 / hw.pair_eval_rate;
        let m = counts.force_kernel_pairs as f64 * BYTES_PER_FORCE_PAIR / hw.mem_bw;
        t.force_kernel = c.max(m) + launch;
    }

    if counts.integrate_particles > 0 {
        let c = counts.integrate_particles as f64 / hw.integrate_rate;
        let m = counts.integrate_particles as f64 * BYTES_PER_INTEGRATE / hw.mem_bw;
        t.integrate = c.max(m) + launch;
    }

    if counts.grid_binned > 0 || counts.sort_elems > 0 {
        t.grid = counts.grid_binned as f64 / hw.grid_rate
            + counts.sort_elems as f64 / hw.sort_rate
            + counts.sort_elems as f64 * BYTES_PER_SORT_ELEM / hw.mem_bw
            + if counts.sort_elems > 0 { 4.0 * launch } else { launch };
    }

    if counts.cell_pair_tests > 0 || counts.cell_force_evals > 0 || counts.cell_visits > 0 {
        // distance tests are ~half the cost of a full LJ pair eval; cell
        // lookups pay memory latency even when the cells are empty
        let c = counts.cell_pair_tests as f64 / (2.0 * hw.pair_eval_rate)
            + counts.cell_force_evals as f64 / hw.pair_eval_rate
            + counts.cell_visits as f64 / hw.cell_visit_rate;
        let m = counts.cell_pair_tests as f64 * BYTES_PER_CELL_TEST / hw.mem_bw;
        t.cell = c.max(m) + launch;
    }

    t
}

/// Modeled device-memory bytes moved per phase — the same byte constants
/// [`simulate`] prices against, exposed so telemetry spans can attribute
/// traffic to the phase that generated it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseBytes {
    pub sort: u64,
    pub traverse: u64,
    pub cell: u64,
    pub force_kernel: u64,
    pub integrate: u64,
}

impl PhaseBytes {
    pub fn total(&self) -> u64 {
        self.sort + self.traverse + self.cell + self.force_kernel + self.integrate
    }
}

/// Attribute one step's modeled memory traffic to its phases.
pub fn phase_bytes(counts: &OpCounts) -> PhaseBytes {
    PhaseBytes {
        sort: (counts.sort_elems as f64 * BYTES_PER_SORT_ELEM) as u64,
        traverse: (counts.aabb_tests as f64 * BYTES_PER_NODE_FETCH
            + counts.sphere_tests as f64 * BYTES_PER_SPHERE_FETCH
            + counts.nbr_list_writes as f64 * BYTES_PER_LIST_WRITE) as u64,
        cell: (counts.cell_pair_tests as f64 * BYTES_PER_CELL_TEST) as u64,
        force_kernel: (counts.force_kernel_pairs as f64 * BYTES_PER_FORCE_PAIR) as u64,
        integrate: (counts.integrate_particles as f64 * BYTES_PER_INTEGRATE) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rtcore::profile::{L40, RTXPRO, TITANRTX};

    fn rt_step_counts() -> OpCounts {
        OpCounts {
            bvh_refit_prims: 100_000,
            aabb_tests: 5_000_000,
            sphere_tests: 800_000,
            rays: 100_000,
            nbr_list_writes: 400_000,
            force_kernel_pairs: 400_000,
            integrate_particles: 100_000,
            kernel_launches: 3,
            interactions: 200_000,
            ..Default::default()
        }
    }

    #[test]
    fn phases_priced_and_total_consistent() {
        let t = simulate(&rt_step_counts(), &RTXPRO);
        assert!(t.refit > 0.0 && t.traverse > 0.0 && t.force_kernel > 0.0);
        assert!(t.build == 0.0 && t.grid == 0.0 && t.cell == 0.0);
        let sum = t.build + t.refit + t.traverse + t.force_kernel + t.integrate + t.grid + t.cell;
        assert!((t.total() - sum).abs() < 1e-15);
        assert!((t.rt_cost() - (t.refit + t.traverse)).abs() < 1e-15);
    }

    #[test]
    fn newer_hardware_is_faster() {
        let c = rt_step_counts();
        let old = simulate(&c, &TITANRTX).total();
        let mid = simulate(&c, &L40).total();
        let new = simulate(&c, &RTXPRO).total();
        assert!(old > mid && mid > new, "{old} {mid} {new}");
    }

    #[test]
    fn build_costs_more_than_refit_per_prim() {
        let build = OpCounts { bvh_built_prims: 1_000_000, ..Default::default() };
        let refit = OpCounts { bvh_refit_prims: 1_000_000, ..Default::default() };
        assert!(simulate(&build, &RTXPRO).build > simulate(&refit, &RTXPRO).refit);
    }

    #[test]
    fn traversal_roofline_picks_bottleneck() {
        // box-test-dominated workload (units are 4-wide node tests)
        let boxy = OpCounts { rays: 10, aabb_tests: 1_000_000_000, ..Default::default() };
        let tb = simulate(&boxy, &RTXPRO).traverse;
        let want_box =
            1e9 * BOX_TESTS_PER_AABB_UNIT / RTXPRO.rt_box_rate + RTXPRO.launch_overhead_s;
        assert!((tb - want_box).abs() < 1e-9);
        // shader-dominated workload (many force evals, few box tests);
        // in-shader evals carry the divergence penalty
        let shady = OpCounts { rays: 10, isect_force_evals: 1_000_000_000, ..Default::default() };
        let ts = simulate(&shady, &RTXPRO).traverse;
        let want = 1e9 * IN_SHADER_DIVERGENCE / RTXPRO.pair_eval_rate + RTXPRO.launch_overhead_s;
        assert!((ts - want).abs() < 1e-9);
    }

    #[test]
    fn empty_counts_cost_nothing() {
        let t = simulate(&OpCounts::default(), &RTXPRO);
        assert_eq!(t.total(), 0.0);
    }

    #[test]
    fn quantized_node_fetch_repriced_at_least_2x() {
        // the quantized layout must fit a cache line and cut the priced
        // node-fetch traffic by >= 2x against the 128-byte calibration
        assert!(std::mem::size_of::<crate::bvh::Bvh4Node>() <= 64);
        assert!(
            BYTES_PER_NODE_FETCH_UNCOMPRESSED / BYTES_PER_NODE_FETCH >= 2.0,
            "{BYTES_PER_NODE_FETCH} B vs uncompressed {BYTES_PER_NODE_FETCH_UNCOMPRESSED} B"
        );
    }

    #[test]
    fn phase_bytes_uses_the_priced_constants() {
        let b = phase_bytes(&rt_step_counts());
        let want_trav = 5_000_000.0 * BYTES_PER_NODE_FETCH
            + 800_000.0 * BYTES_PER_SPHERE_FETCH
            + 400_000.0 * BYTES_PER_LIST_WRITE;
        assert_eq!(b.traverse, want_trav as u64);
        assert_eq!(b.force_kernel, (400_000.0 * BYTES_PER_FORCE_PAIR) as u64);
        assert_eq!(b.integrate, (100_000.0 * BYTES_PER_INTEGRATE) as u64);
        assert_eq!(b.sort, 0);
        assert_eq!(b.cell, 0);
        assert_eq!(phase_bytes(&OpCounts::default()).total(), 0);
    }
}
