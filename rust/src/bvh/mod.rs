//! The BVH substrate — our stand-in for the GPU RT cores' acceleration
//! structure.
//!
//! The paper manages the OptiX BVH through exactly two operations: **build**
//! (full reconstruction, optimal tree for the current particle positions)
//! and **update** (refit: recompute node bounds over the existing topology).
//! We reproduce both, plus a stack traversal with *exact operation counters*
//! (AABB tests, sphere tests) that feed the RT-core timing model
//! ([`crate::rtcore`]). Refit-induced degradation — the phenomenon the
//! `gradient` optimizer exploits — emerges structurally: as particles move,
//! refitted node bounds overlap more and traversal touches more nodes.
//!
//! Builds are multi-threaded (see [`builder`]) and queries run through the
//! batched, allocation-free traversal engine (see [`traverse`]:
//! [`traverse::QueryScratch`] / [`Bvh::query_batch`]); both scale with
//! `ORCS_THREADS`.

pub mod builder;
pub mod quality;
pub mod traverse;

use crate::core::aabb::Aabb;
use crate::core::vec3::Vec3;

/// Maximum primitives per leaf. 4 mirrors typical hardware BVH widths.
pub const LEAF_SIZE: usize = 4;

/// One BVH node. Children of internal nodes are allocated consecutively
/// (`left`, `left + 1`), and always at higher indices than their parent, so
/// a reverse-index sweep is a valid bottom-up order (used by refit).
#[derive(Clone, Copy, Debug)]
pub struct Node {
    pub aabb: Aabb,
    /// Internal: index of the left child (right = left + 1).
    /// Leaf: first index into [`Bvh::prim_order`].
    pub left_first: u32,
    /// 0 for internal nodes; primitive count for leaves.
    pub count: u32,
}

impl Node {
    #[inline(always)]
    pub fn is_leaf(&self) -> bool {
        self.count > 0
    }
}

/// Build heuristic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BuildKind {
    /// Median split on the longest centroid axis — fast, decent quality
    /// (models hardware LBVH-style builders).
    Median,
    /// Binned surface-area heuristic — slower build, better tree (models
    /// high-quality builds). 16 bins.
    BinnedSah,
    /// Morton-order linear BVH (HLBVH-family, paper refs [29][32]): radix
    /// sort primitives by Z-order, then split sorted ranges at their
    /// midpoint. Fastest build, lowest quality — the hardware-builder
    /// extreme of the build/quality trade-off ablation.
    Lbvh,
}

/// A bounding volume hierarchy over particle search spheres.
#[derive(Clone, Debug)]
pub struct Bvh {
    pub nodes: Vec<Node>,
    /// Permutation of primitive ids; leaves reference ranges of it.
    pub prim_order: Vec<u32>,
    pub n_prims: usize,
    pub kind: BuildKind,
    /// Number of refits applied since the last full build.
    pub refits_since_build: u32,
}

impl Bvh {
    /// Number of nodes (internal + leaf).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Root bounding box.
    pub fn root_aabb(&self) -> Aabb {
        self.nodes[0].aabb
    }

    /// Refit ("update" in RT-core terms): recompute every node's AABB from
    /// current sphere positions without changing the topology. O(nodes).
    pub fn refit(&mut self, pos: &[Vec3], radius: &[f32]) {
        debug_assert_eq!(pos.len(), self.n_prims);
        for i in (0..self.nodes.len()).rev() {
            let node = self.nodes[i];
            let mut bb = Aabb::EMPTY;
            if node.is_leaf() {
                let first = node.left_first as usize;
                for k in first..first + node.count as usize {
                    let p = self.prim_order[k] as usize;
                    bb.grow(&Aabb::of_sphere(pos[p], radius[p]));
                }
            } else {
                // children have higher indices -> already refit
                bb.grow(&self.nodes[node.left_first as usize].aabb);
                bb.grow(&self.nodes[node.left_first as usize + 1].aabb);
            }
            self.nodes[i].aabb = bb;
        }
        self.refits_since_build += 1;
    }

    /// Validate structural invariants (tests / debug builds).
    pub fn check_invariants(&self, pos: &[Vec3], radius: &[f32]) -> Result<(), String> {
        // prim_order is a permutation
        let mut seen = vec![false; self.n_prims];
        for &p in &self.prim_order {
            let p = p as usize;
            if p >= self.n_prims {
                return Err(format!("prim id {p} out of range"));
            }
            if seen[p] {
                return Err(format!("prim id {p} duplicated"));
            }
            seen[p] = true;
        }
        if !seen.iter().all(|&s| s) {
            return Err("prim_order not a full permutation".into());
        }
        // every node's AABB contains its content; children after parents
        for (i, n) in self.nodes.iter().enumerate() {
            if n.is_leaf() {
                let first = n.left_first as usize;
                if first + n.count as usize > self.prim_order.len() {
                    return Err(format!("leaf {i} range out of bounds"));
                }
                for k in first..first + n.count as usize {
                    let p = self.prim_order[k] as usize;
                    let sb = Aabb::of_sphere(pos[p], radius[p]);
                    if !contains_box(&n.aabb, &sb) {
                        return Err(format!("leaf {i} does not bound prim {p}"));
                    }
                }
            } else {
                let l = n.left_first as usize;
                if l <= i || l + 1 >= self.nodes.len() {
                    return Err(format!("node {i} bad child index {l}"));
                }
                for c in [l, l + 1] {
                    if !contains_box(&n.aabb, &self.nodes[c].aabb) {
                        return Err(format!("node {i} does not bound child {c}"));
                    }
                }
            }
        }
        Ok(())
    }
}

fn contains_box(outer: &Aabb, inner: &Aabb) -> bool {
    const EPS: f32 = 1e-3;
    inner.is_empty()
        || (outer.lo.x <= inner.lo.x + EPS
            && outer.lo.y <= inner.lo.y + EPS
            && outer.lo.z <= inner.lo.z + EPS
            && outer.hi.x >= inner.hi.x - EPS
            && outer.hi.y >= inner.hi.y - EPS
            && outer.hi.z >= inner.hi.z - EPS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Rng;

    fn random_scene(n: usize, seed: u64) -> (Vec<Vec3>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| {
                Vec3::new(
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                    rng.range_f32(0.0, 100.0),
                )
            })
            .collect();
        let radius = (0..n).map(|_| rng.range_f32(0.5, 5.0)).collect();
        (pos, radius)
    }

    #[test]
    fn build_invariants_hold_both_kinds() {
        for kind in [BuildKind::Median, BuildKind::BinnedSah] {
            let (pos, radius) = random_scene(500, 9);
            let bvh = Bvh::build(&pos, &radius, kind);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.n_prims, 500);
            assert_eq!(bvh.refits_since_build, 0);
        }
    }

    #[test]
    fn refit_keeps_invariants_after_motion() {
        let (mut pos, radius) = random_scene(300, 10);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::BinnedSah);
        let mut rng = Rng::new(77);
        for round in 1..=5 {
            for p in pos.iter_mut() {
                *p += Vec3::new(
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                    rng.range_f32(-2.0, 2.0),
                );
            }
            bvh.refit(&pos, &radius);
            bvh.check_invariants(&pos, &radius).unwrap();
            assert_eq!(bvh.refits_since_build, round);
        }
    }

    #[test]
    fn single_and_tiny_inputs() {
        let pos = vec![Vec3::splat(1.0)];
        let radius = vec![2.0];
        let bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        bvh.check_invariants(&pos, &radius).unwrap();
        assert_eq!(bvh.node_count(), 1);
        assert!(bvh.nodes[0].is_leaf());
    }

    #[test]
    fn refit_grows_root_when_particles_spread() {
        let (mut pos, radius) = random_scene(100, 11);
        let mut bvh = Bvh::build(&pos, &radius, BuildKind::Median);
        let before = bvh.root_aabb().surface_area();
        for p in pos.iter_mut() {
            *p = *p * 2.0; // spread out
        }
        bvh.refit(&pos, &radius);
        assert!(bvh.root_aabb().surface_area() > before);
        bvh.check_invariants(&pos, &radius).unwrap();
    }
}
