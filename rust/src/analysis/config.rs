//! Lint configuration: path scopes + the checked-in allowlist.
//!
//! Loaded from `lint.toml` at the repo root via a tiny TOML-subset parser
//! (the offline vendor set has no `toml` crate — same spirit as the
//! hand-rolled CLI). Supported subset: `#` comments, `[section]`,
//! `[[array-of-tables]]`, `key = "string"`, and `key = ["a", "b"]`
//! single-line string arrays. That is exactly what `lint.toml` uses;
//! anything else is a hard parse error so drift is caught in CI.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::rules;

/// One `[[allow]]` entry: suppress `rule` findings under `path`.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    /// Rule id (`P-PANIC`, ...) or `*` for all rules.
    pub rule: String,
    /// Path prefix relative to the lint root (`frnn/cell_list.rs`, `bvh`).
    pub path: String,
    /// Mandatory human rationale — empty reasons are a parse error.
    pub reason: String,
}

/// Parsed lint configuration.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Panic-safety scope: code reachable from `Backend::step` and the
    /// engines' `run()` (the PR 4 `SimError` contract).
    pub step_path: Vec<String>,
    /// Determinism scope: code that must be bitwise reproducible across
    /// `ORCS_THREADS` and shard counts.
    pub det_path: Vec<String>,
    /// CSR offset/merge scope for the narrowing-cast rule.
    pub csr_path: Vec<String>,
    /// Checked-in suppressions.
    pub allow: Vec<AllowEntry>,
}

impl Default for LintConfig {
    /// Scope defaults mirroring the checked-in `lint.toml`, so `orcs lint`
    /// still enforces the repo contract when run without a config file.
    fn default() -> Self {
        let v = |xs: &[&str]| xs.iter().map(|s| s.to_string()).collect();
        LintConfig {
            step_path: v(&[
                "bvh",
                "coordinator/engine.rs",
                "frnn",
                "gradient",
                "parallel.rs",
                "physics",
                "resilience",
                "runtime/kernels.rs",
                "shard",
                "telemetry",
            ]),
            det_path: v(&["bvh", "frnn", "gradient", "physics", "shard", "telemetry"]),
            csr_path: v(&[
                "frnn/cell_list.rs",
                "frnn/rt_ref.rs",
                "parallel.rs",
                "shard/engine.rs",
            ]),
            allow: Vec::new(),
        }
    }
}

impl LintConfig {
    /// Does `rel` fall under any prefix in `scope`? A prefix of `"."`
    /// matches everything; a `.rs` prefix must match the file exactly;
    /// otherwise it matches the directory subtree.
    pub fn in_scope(rel: &str, scope: &[String]) -> bool {
        scope.iter().any(|p| path_matches(rel, p))
    }

    /// Is the finding `(rule, rel)` suppressed by a config allow entry?
    pub fn allowed(&self, rule: &str, rel: &str) -> bool {
        self.allow
            .iter()
            .any(|a| (a.rule == "*" || a.rule == rule) && path_matches(rel, &a.path))
    }

    /// Load from a `lint.toml` file.
    pub fn load(path: &Path) -> Result<LintConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading lint config {}", path.display()))?;
        parse_toml(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// Prefix match for scope/allow paths (see [`LintConfig::in_scope`]).
pub fn path_matches(rel: &str, prefix: &str) -> bool {
    prefix == "."
        || rel == prefix
        || rel.strip_prefix(prefix).is_some_and(|rest| rest.starts_with('/'))
}

/// Parse the TOML subset described in the module docs.
pub fn parse_toml(text: &str) -> Result<LintConfig> {
    #[derive(PartialEq)]
    enum Section {
        None,
        Paths,
        Allow,
    }
    let mut cfg = LintConfig::default();
    let mut paths_seen = false;
    let mut section = Section::None;
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_toml_comment(raw).trim().to_string();
        let at = |msg: &str| anyhow::anyhow!("lint.toml line {}: {msg}", ln + 1);
        if line.is_empty() {
            continue;
        }
        if line == "[[allow]]" {
            cfg.allow.push(AllowEntry {
                rule: String::new(),
                path: String::new(),
                reason: String::new(),
            });
            section = Section::Allow;
            continue;
        }
        if line == "[paths]" {
            // an explicit [paths] section replaces the baked-in defaults
            if !paths_seen {
                cfg.step_path.clear();
                cfg.det_path.clear();
                cfg.csr_path.clear();
                paths_seen = true;
            }
            section = Section::Paths;
            continue;
        }
        if line.starts_with('[') {
            bail!(at(&format!("unknown section {line}")));
        }
        let (key, value) = line.split_once('=').ok_or_else(|| at("expected key = value"))?;
        let (key, value) = (key.trim(), value.trim());
        match section {
            Section::None => bail!(at("key outside a section")),
            Section::Paths => {
                let items = parse_str_array(value).ok_or_else(|| at("expected a string array"))?;
                match key {
                    "step" => cfg.step_path = items,
                    "det" => cfg.det_path = items,
                    "csr" => cfg.csr_path = items,
                    other => bail!(at(&format!("unknown [paths] key {other}"))),
                }
            }
            Section::Allow => {
                let s = parse_str(value).ok_or_else(|| at("expected a quoted string"))?;
                let entry = cfg.allow.last_mut().ok_or_else(|| at("no open [[allow]]"))?;
                match key {
                    "rule" => entry.rule = s,
                    "path" => entry.path = s,
                    "reason" => entry.reason = s,
                    other => bail!(at(&format!("unknown [[allow]] key {other}"))),
                }
            }
        }
    }
    for (k, a) in cfg.allow.iter().enumerate() {
        if a.rule.is_empty() || a.path.is_empty() {
            bail!("lint.toml: [[allow]] #{} needs both rule and path", k + 1);
        }
        if a.reason.trim().is_empty() {
            bail!("lint.toml: [[allow]] {} on {} has no reason", a.rule, a.path);
        }
        if a.rule != "*" && !rules::is_known_rule(&a.rule) {
            bail!(
                "lint.toml: [[allow]] #{} names unknown rule {} (known: {})",
                k + 1,
                a.rule,
                rules::rule_ids().join(", ")
            );
        }
    }
    Ok(cfg)
}

/// Strip a `#` comment, ignoring `#` inside double quotes.
fn strip_toml_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut prev_backslash = false;
    for (k, c) in line.char_indices() {
        match c {
            '"' if !prev_backslash => in_str = !in_str,
            '#' if !in_str => return &line[..k],
            _ => {}
        }
        prev_backslash = c == '\\' && !prev_backslash;
    }
    line
}

/// `"value"` → `value` (basic escapes only).
fn parse_str(v: &str) -> Option<String> {
    let inner = v.strip_prefix('"')?.strip_suffix('"')?;
    Some(inner.replace("\\\"", "\"").replace("\\\\", "\\"))
}

/// `["a", "b"]` → `vec!["a", "b"]` (single line, string elements).
fn parse_str_array(v: &str) -> Option<Vec<String>> {
    let inner = v.strip_prefix('[')?.strip_suffix(']')?.trim();
    if inner.is_empty() {
        return Some(Vec::new());
    }
    inner.split(',').map(|item| parse_str(item.trim())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paths_and_allows() {
        let cfg = parse_toml(
            "# comment\n[paths]\nstep = [\"bvh\", \"shard/engine.rs\"]\ndet = []\ncsr = []\n\n\
             [[allow]]\nrule = \"D-WALL-CLOCK\"\npath = \"frnn/mod.rs\"\nreason = \"metering\"\n",
        )
        .unwrap();
        assert_eq!(cfg.step_path, vec!["bvh", "shard/engine.rs"]);
        assert!(cfg.det_path.is_empty());
        assert_eq!(cfg.allow.len(), 1);
        assert!(cfg.allowed("D-WALL-CLOCK", "frnn/mod.rs"));
        assert!(!cfg.allowed("D-WALL-CLOCK", "frnn/mod_b.rs"));
        assert!(!cfg.allowed("P-PANIC", "frnn/mod.rs"));
    }

    #[test]
    fn prefix_matching() {
        assert!(path_matches("bvh/builder.rs", "bvh"));
        assert!(path_matches("bvh/builder.rs", "bvh/builder.rs"));
        assert!(path_matches("anything.rs", "."));
        assert!(!path_matches("bvh2/builder.rs", "bvh"));
        assert!(!path_matches("bvh/builder.rs", "bvh/build"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[paths]\nstep = nope\n").is_err());
        assert!(parse_toml("step = [\"x\"]\n").is_err(), "key outside section");
        assert!(parse_toml("[[allow]]\nrule = \"P-PANIC\"\n").is_err(), "missing path");
        assert!(
            parse_toml("[[allow]]\nrule = \"P-PANIC\"\npath = \"x.rs\"\nreason = \"\"\n").is_err(),
            "empty reason"
        );
        assert!(
            parse_toml("[[allow]]\nrule = \"NOT-A-RULE\"\npath = \"x\"\nreason = \"r\"\n").is_err(),
            "unknown rule"
        );
    }

    #[test]
    fn defaults_apply_without_paths_section() {
        let cfg = parse_toml("[[allow]]\nrule = \"*\"\npath = \".\"\nreason = \"r\"\n").unwrap();
        assert!(!cfg.step_path.is_empty(), "defaults kept when [paths] absent");
        assert!(cfg.allowed("P-PANIC", "whatever/file.rs"));
    }
}
