//! RT-REF — the base RT-core FRNN idea of prior work [10, 11, 12, 24]:
//! traversal fills a neighbor list, then a separate compute kernel
//! evaluates forces from the list and another one integrates.
//!
//! The fixed-slot GPU allocation is `n * k_max * 4` bytes; when a scene's
//! densest particle pushes `k_max` toward `n` (Cluster + log-normal radii),
//! the allocation exceeds device memory — the OOM cells of Table 2 and
//! Fig. 13. We track the same quantity and fail the same way.
//!
//! Variable-radius subtlety (paper Fig. 5): `i`'s ray only discovers `j`
//! when `|d| < r_j`. If additionally `|d| >= r_i`, `j`'s ray can *not*
//! discover `i`, so the detecting thread must also append itself to `j`'s
//! list — an atomic cross-insert on real hardware, counted as such.

use crate::frnn::rt_common::{fold_stats, gamma_trigger, launch_rays, BvhManager};
use crate::frnn::zorder::ZOrderCache;
use crate::frnn::{Backend, NeighborLists, StepCtx, StepResult, WallPhases};
use crate::gradient::RebuildPolicy;
use crate::physics::state::SimState;
use crate::resilience::{SimError, SimResult};
use crate::rtcore::OpCounts;
use crate::telemetry::wallclock::WallTimer;

pub struct RtRef {
    mgr: BvhManager,
    /// Running worst-case list width (real implementations size the fixed
    /// allocation from it and must re-allocate upward).
    k_max_seen: usize,
    /// Per-step Morton keys + permutation, shared by the LBVH build path
    /// and the query sweep (one sort per step instead of one per phase).
    zcache: ZOrderCache,
}

impl RtRef {
    pub fn new(policy: Box<dyn RebuildPolicy>) -> Self {
        RtRef { mgr: BvhManager::new(policy), k_max_seen: 0, zcache: ZOrderCache::new() }
    }

    pub fn policy_name(&self) -> String {
        self.mgr.policy.name()
    }
}

impl Backend for RtRef {
    fn name(&self) -> &'static str {
        "RT-REF"
    }

    fn step(&mut self, state: &mut SimState, ctx: &mut StepCtx) -> SimResult<StepResult> {
        let mut counts = OpCounts::default();
        let mut wall = WallPhases::default();
        let n = state.n();

        // Phase 0: one Morton keying + sort for the whole step, shared by
        // the (LBVH) build and the query sweep below. Its wall time is
        // charged to the search phase (it schedules the sweep).
        let t_sort = WallTimer::start();
        self.zcache.compute(&state.pos, state.box_l, ctx.threads);
        let sort_wall = t_sort.elapsed_s();
        debug_assert_eq!(self.zcache.order().len(), n);

        // Phase 1: BVH maintenance under the rebuild policy.
        let t0 = WallTimer::start();
        let action = self.mgr.prepare_with(
            &state.pos,
            &state.radius,
            &mut counts,
            ctx.threads,
            false,
            Some(self.zcache.order()),
        );
        wall.bvh = t0.elapsed_s();

        // Phase 2: batched ray traversal, swept in Morton order of the
        // query positions (RTNN-style coherence: consecutive rays enter the
        // same subtrees, so BVH4 node fetches stay cache-hot). Each chunk
        // emits its particle ids plus a flat (per-particle count, item)
        // stream and its cross-inserts; the CSR lists are then assembled
        // directly with a count-then-fill two-pass keyed by those ids — no
        // per-particle Vec, no intermediate Vec<Vec<u32>>, and the scatter
        // lands results back in particle order.
        let t1 = WallTimer::start();
        let bvh = self.mgr.bvh();
        let trigger = gamma_trigger(state);
        struct ChunkOut {
            /// Particle ids swept by this chunk (Morton order).
            ids: Vec<u32>,
            /// Per-particle hit counts, parallel to `ids`.
            lens: Vec<u32>,
            /// Flat neighbor ids in discovery order.
            items: Vec<u32>,
            /// (dst list, inserted id) — atomic appends on real hardware.
            cross: Vec<(u32, u32)>,
        }
        let (chunks, stats) = bvh.query_batch_with_order(
            self.zcache.order(),
            ctx.threads,
            || (),
            |_, scratch, ids| {
                let mut out = ChunkOut {
                    ids: ids.to_vec(),
                    lens: Vec::with_capacity(ids.len()),
                    items: Vec::new(),
                    cross: Vec::new(),
                };
                for &iu in ids {
                    let i = iu as usize;
                    let before = out.items.len();
                    let r_i = state.radius[i];
                    launch_rays(
                        bvh,
                        i,
                        &state.pos,
                        &state.radius,
                        state.boundary,
                        state.box_l,
                        trigger,
                        scratch,
                        |j, dx| {
                            out.items.push(j as u32);
                            // cross-insert when j's ray cannot see i
                            if dx.norm2() >= r_i * r_i {
                                out.cross.push((j as u32, iu));
                            }
                        },
                    );
                    // lint:allow(P-CAST-NARROW): per-particle degree < 2^32 by the OOM check
                    out.lens.push((out.items.len() - before) as u32);
                }
                out
            },
        );
        fold_stats(&mut counts, &stats);

        // Pass 1: per-particle totals (ray hits + incoming cross-inserts).
        // All direct lens are assigned before any cross increment: a
        // cross-insert may target a particle swept by a *later* chunk, and
        // interleaving would let that chunk's plain assignment clobber the
        // already-reserved extra slot (shortening the offsets array and
        // corrupting the pass-2 scatter).
        let mut lens = vec![0u32; n];
        for c in &chunks {
            for (k, &len) in c.lens.iter().enumerate() {
                lens[c.ids[k] as usize] = len;
            }
        }
        let mut cross_inserts = 0u64;
        for c in &chunks {
            for &(dst, _) in &c.cross {
                lens[dst as usize] += 1;
                cross_inserts += 1;
            }
        }
        // Offsets via the three-phase parallel exclusive scan — the serial
        // accumulation here was the next bottleneck at n = 1M (the two
        // counting loops above touch only the sparse cross lists; this scan
        // walks the full n-length array).
        let offsets = crate::parallel::exclusive_scan_u32(&lens, ctx.threads);
        let total = offsets.last().copied().unwrap_or(0);
        // Pass 2: scatter items into place. Chunks come back in chunk order
        // and the Morton permutation is thread-count independent, so the
        // fill (and thus the physics downstream) is deterministic no matter
        // which worker produced which chunk or how many threads ran.
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut items = vec![0u32; total as usize];
        for c in &chunks {
            let mut consumed = 0usize;
            for (k, &len) in c.lens.iter().enumerate() {
                let i = c.ids[k] as usize;
                let dst = cursor[i] as usize;
                items[dst..dst + len as usize]
                    .copy_from_slice(&c.items[consumed..consumed + len as usize]);
                cursor[i] += len;
                consumed += len as usize;
            }
        }
        for c in &chunks {
            for &(dst, src) in &c.cross {
                let d = dst as usize;
                items[cursor[d] as usize] = src;
                cursor[d] += 1;
            }
        }
        let mut nl = NeighborLists { offsets, items };
        // Canonical ascending-id order per list: the force kernel sums
        // contributions in list order, so this fixes the f32 accumulation
        // order independently of ray discovery order — the invariant the
        // sharded engine relies on to be bitwise identical to this path.
        nl.sort_segments(ctx.threads);
        counts.nbr_list_writes += nl.total_entries() as u64;
        counts.atomic_adds += cross_inserts; // atomic appends on real hardware
        self.k_max_seen = self.k_max_seen.max(nl.k_max());
        let list_bytes = (n as u64) * (self.k_max_seen as u64) * 4;
        counts.nbr_list_bytes_peak = list_bytes;
        // every interacting pair ends up in both endpoint lists exactly once
        counts.interactions += nl.total_entries() as u64 / 2;
        wall.search = sort_wall + t1.elapsed_s();

        if ctx.check_oom && list_bytes > ctx.effective_vram() {
            self.mgr.observe(action, &counts, ctx.hw);
            return Ok(StepResult {
                counts,
                bvh_action: Some(action),
                oom_bytes: Some(list_bytes),
                wall,
            });
        }

        // Phase 3: separate force kernel over the lists (XLA or Rust).
        // The paper's kernel reads the *fixed-slot* n x k_max allocation —
        // padding slots are fetched and masked like real ones — so the
        // simulated cost is priced on n * k_max, not on the CSR entry
        // count. This is what makes RT-REF lose to ORCS-forces on skewed
        // (log-normal) neighbor distributions (Table 2, Figs 9-10).
        let t2 = WallTimer::start();
        state.force = ctx.kernels.lj_forces(state, &nl, &mut counts).map_err(SimError::fatal)?;
        counts.force_kernel_pairs += (n as u64) * (nl.k_max() as u64);
        wall.force = t2.elapsed_s();

        // Phase 4: integration kernel.
        let t3 = WallTimer::start();
        ctx.kernels.integrate(state, &mut counts).map_err(SimError::fatal)?;
        wall.integrate = t3.elapsed_s();

        self.mgr.observe(action, &counts, ctx.hw);
        Ok(StepResult { counts, bvh_action: Some(action), oom_bytes: None, wall })
    }

    fn invalidate_bvh(&mut self) {
        self.mgr.invalidate();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{Boundary, RadiusDist, SimConfig};
    use crate::frnn::{brute, RustKernels};
    use crate::gradient::FixedKPolicy;
    use crate::rtcore::profile::RTXPRO;

    fn run_one(
        n: usize,
        boundary: Boundary,
        radius: RadiusDist,
    ) -> (SimState, SimState, StepResult) {
        let cfg = SimConfig {
            n,
            boundary,
            radius_dist: radius,
            box_l: 100.0,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let want = {
            let mut s2 = state.clone();
            s2.force = brute::forces(&s2);
            crate::physics::integrator::step(&mut s2);
            s2
        };
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        (state, want, r)
    }

    #[test]
    fn matches_brute_force_uniform_radius() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let (state, want, r) = run_one(250, boundary, RadiusDist::Const(8.0));
            assert!(r.counts.nbr_list_writes > 0);
            for i in 0..state.n() {
                assert!(
                    (state.pos[i] - want.pos[i]).norm() < 1e-3,
                    "{boundary:?} particle {i}"
                );
            }
        }
    }

    #[test]
    fn matches_brute_force_variable_radius() {
        for boundary in [Boundary::Wall, Boundary::Periodic] {
            let (state, want, r) = run_one(250, boundary, RadiusDist::Uniform(2.0, 14.0));
            // variable radius must trigger cross-inserts (asymmetric pairs)
            assert!(r.counts.atomic_adds > 0, "expected cross-inserts");
            for i in 0..state.n() {
                assert!(
                    (state.pos[i] - want.pos[i]).norm() < 1e-3,
                    "{boundary:?} particle {i}"
                );
            }
        }
    }

    #[test]
    fn oom_fires_when_list_exceeds_vram() {
        let cfg = SimConfig {
            n: 100,
            boundary: Boundary::Wall,
            radius_dist: RadiusDist::Const(50.0), // dense: k_max ~ n
            box_l: 20.0,                          // everything interacts
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        for p in state.pos.iter_mut() {
            p.x = p.x.rem_euclid(20.0);
            p.y = p.y.rem_euclid(20.0);
            p.z = p.z.rem_euclid(20.0);
        }
        // a tiny synthetic device: 1 KB of VRAM
        static TINY: crate::rtcore::HwProfile = {
            let mut p = crate::rtcore::profile::RTXPRO;
            p.vram_bytes = 1024;
            p
        };
        let kernels = RustKernels { threads: 1 };
        let mut ctx = StepCtx {
            threads: 1,
            kernels: &kernels,
            hw: &TINY,
            check_oom: true,
            vram_budget: None,
        };
        let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert!(r.oom_bytes.is_some(), "expected OOM, got {:?}", r.counts.nbr_list_bytes_peak);
    }

    #[test]
    fn csr_handles_empty_and_singleton_scenes() {
        // n = 0 (used to panic in the BVH build) and n = 1 (no possible
        // neighbor): the CSR assembly must produce the trivial offsets
        // array and a fully-zero step without panicking.
        for n in [0usize, 1] {
            let cfg = SimConfig {
                n,
                boundary: Boundary::Wall,
                radius_dist: RadiusDist::Const(5.0),
                box_l: 100.0,
                ..SimConfig::default()
            };
            let mut state = SimState::from_config(&cfg);
            let kernels = RustKernels { threads: 2 };
            let mut ctx = StepCtx {
                threads: 2,
                kernels: &kernels,
                hw: &RTXPRO,
                check_oom: false,
                vram_budget: None,
            };
            let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
            for _ in 0..3 {
                let r = backend.step(&mut state, &mut ctx).unwrap();
                assert_eq!(r.counts.nbr_list_writes, 0, "n={n}");
                assert_eq!(r.counts.interactions, 0, "n={n}");
                assert_eq!(r.counts.atomic_adds, 0, "n={n}");
                assert!(r.oom_bytes.is_none());
            }
            assert!(state.is_finite());
            assert_eq!(state.n(), n);
        }
    }

    #[test]
    fn csr_all_isolated_particles_produce_zero_lists() {
        // Tiny radii on a sparse lattice: every neighbor list is empty, so
        // the offsets array is all zeros and no items are written.
        let cfg = SimConfig {
            n: 64,
            boundary: Boundary::Wall,
            radius_dist: RadiusDist::Const(0.01),
            box_l: 1000.0,
            particle_dist: crate::core::config::ParticleDist::Lattice,
            ..SimConfig::default()
        };
        let mut state = SimState::from_config(&cfg);
        let kernels = RustKernels { threads: 2 };
        let mut ctx = StepCtx {
            threads: 2,
            kernels: &kernels,
            hw: &RTXPRO,
            check_oom: false,
            vram_budget: None,
        };
        let mut backend = RtRef::new(Box::new(FixedKPolicy::new(4)));
        let r = backend.step(&mut state, &mut ctx).unwrap();
        assert_eq!(r.counts.nbr_list_writes, 0);
        assert_eq!(r.counts.interactions, 0);
        // forces over empty lists are exactly zero -> free flight
        assert!(state.force.iter().all(|f| *f == crate::core::vec3::Vec3::ZERO));
        assert!(state.is_finite());
    }

    #[test]
    fn interactions_counted_once_per_pair() {
        let (_, _, r) = run_one(200, Boundary::Periodic, RadiusDist::Const(10.0));
        let cfg = SimConfig {
            n: 200,
            boundary: Boundary::Periodic,
            radius_dist: RadiusDist::Const(10.0),
            box_l: 100.0,
            ..SimConfig::default()
        };
        let state = SimState::from_config(&cfg);
        let want =
            brute::count_interactions(&state.pos, &state.radius, state.boundary, state.box_l);
        assert_eq!(r.counts.interactions, want);
    }
}
